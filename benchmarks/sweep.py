"""Shape-class batched sweep benchmark (the BENCH_sweep.json record).

The 45-cell perf-tracking matrix (5 sync/topology schemes x 3 quantization
levels x 3 learning rates, qsgd+EF), replicated over 2 problem seeds (90
cells over 2 distinct problem instances), spans exactly 5 shape classes —
problem data (quadratic A/b, x*) is traced through the Problem protocol, so
seed replicas share the class programs (10 compiles before data threading).  The batched engine must compile
once per class — not once per cell — and beat the per-cell PR 2 path by
>= 5x wall-clock while reproducing its results to numerical tolerance.
Asserted here (``sweep/claims_validated``) and written to
``BENCH_sweep.json`` at the repo root for the across-PR trajectory.

``run(no_speedup=True)`` (the ``--no-speedup`` aggregator flag) skips the
expensive per-cell baseline and records only the batched numbers.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import Row

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "BENCH_sweep.json")


def run(no_speedup: bool = False) -> list[Row]:
    from repro.experiments.runner import measure_sweep_speedup, sweep_matrix_45

    # two problem seeds: 90 cells over 2 distinct problem instances still
    # compile once per shape class (10 compiles before data threading) —
    # problem data (A/b, x*) is traced
    rec = measure_sweep_speedup(sweep_matrix_45(problem_seeds=(0, 1)),
                                replicas=3, percell=not no_speedup)
    rows = [
        Row("sweep/shape_classes", 0.0,
            f"{rec['n_cells']} cells ({rec['n_problem_instances']} problem "
            f"instances) -> {rec['n_shape_classes']} classes "
            f"(were {rec['n_classes_without_shared_problems']} before "
            f"problem-data threading), {rec['compiles_batched']} compiles"),
        Row("sweep/batched", rec["batched_s"] * 1e6,
            f"{rec['cells_per_s_batched']:.1f} cells/s "
            f"({rec['n_cells']} cells x {rec['replicas']} replicas, "
            f"{rec['steps']} steps)"),
    ]
    assert rec["compiles_batched"] == rec["n_shape_classes"], rec

    if not no_speedup:
        rows.append(Row(
            "sweep/speedup_vs_percell", rec["percell_s"] * 1e6,
            f"{rec['speedup']:.1f}x over {rec['compiles_percell']} per-cell "
            f"compiles; max dev loss={rec['max_rel_dev_loss']:.1e} "
            f"bits={rec['max_rel_dev_bits']:.1e}"))
        # acceptance: >= 5x, per-cell results reproduced to tolerance
        assert rec["speedup"] >= 5.0, rec
        assert rec["max_rel_dev_loss"] < 2e-4, rec
        assert rec["max_rel_dev_bits"] < 1e-6, rec

    with open(BENCH_PATH, "w") as f:
        json.dump(rec, f, indent=2)
    rows.append(Row("sweep/claims_validated", 0.0, True))
    return rows
