"""Elastic-worker churn axis made executable: fault injection + masked
aggregation + adaptive compression policies — ``BENCH_churn.json``.

Engine leg (always runs): {static qsgd s=4, static qsgd s=16, adaptive_qsgd}
x {0%, 10%, 30%} per-step dropout, all nine cells churn-class members (the
0% cells set ``churn=True`` explicitly), executed through the shape-class
batched scan engine.  Asserts:

* the sweep compiles once per shape class (qsgd levels are traced, so both
  static policies share one class; adaptive_qsgd is its own family) — NOT
  once per dropout rate;
* every trajectory is finite and every cell still converges (final loss
  below its start);
* the variance-feedback adaptive policy beats at least one static policy on
  final loss under 30% dropout — the level count rises with the churn-
  inflated EF residual dispersion, where a static aggressive quantizer
  compounds masked-round noise.

Trainer leg (needs >=2 devices, else a skip row): {qsgd, adaptive_qsgd,
size_adaptive} x {0%, 30%} on the real mesh — builds at most one bundle per
shape class and every loss stays finite.

Rejoin leg (PR 8): the drop-and-rejoin protocol priced and measured on all
three substrates.

Integrity leg (ISSUE 10): 10% in-domain payload corruption priced and
measured — engine cells converge within 2x of their clean twins with
quarantine tallies booked (the adaptive policy included), the timeline's
quarantined-wire figure tracks the closed-form prediction within 2x, and
the trainer cell (needs >=2 devices, else a skip row) reports measured
quarantine accounting next to the closed-form upper bound.

* engine: local-SGD cells under a windowed 30% dropout, ``reset`` vs
  ``pull_avg`` — both converge, the policy is structural (one compile per
  policy), and pull_avg's live-set download is charged in the bit ledger;
* timeline: predicted vs measured resync overhead (event count, seconds,
  bytes) for both policies — the analytic event-count estimate stays within
  2x of one sampled event stream;
* trainer (needs >=2 devices): the three formerly-rejected combos —
  PowerSGD under churn, CHOCO gossip under churn x both rejoin policies,
  and masked runtime parameter averaging (local sync) x both — run
  end-to-end with finite losses, at most one build per shape class, and
  each churn cell reports its live fraction, alive-weighted wire figure
  and the separately-booked resync channel.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import Row
from repro.experiments import Scenario

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "BENCH_churn.json")

DROPOUTS = (0.0, 0.1, 0.3)
#: policy axis: two static QSGD operating points + the variance-feedback one
POLICIES = (
    ("static_qsgd4", "qsgd", {"levels": 4}),
    ("static_qsgd16", "qsgd", {"levels": 16}),
    ("adaptive_qsgd", "adaptive_qsgd", {"var_target": 0.5}),
)


def churn_matrix(*, steps: int = 250, n_workers: int = 8, seed: int = 0) -> list[Scenario]:
    """3 policies x 3 dropout rates = 9 cells, 2 engine shape classes."""
    cells = []
    for _, comp, kw in POLICIES:
        for rate in DROPOUTS:
            cells.append(Scenario(
                sync="bsp", n_workers=n_workers, steps=steps, lr=0.05,
                compressor=comp, compressor_kwargs=kw, error_feedback=True,
                churn=True, dropout_rate=rate, seed=seed))
    return cells


def _steps_to(loss: np.ndarray, target: float) -> int:
    hit = np.nonzero(loss <= target)[0]
    return int(hit[0]) if hit.size else -1


def _engine_leg() -> tuple[dict, list[Row]]:
    from repro.core.simulate import engine_cache_clear, engine_cache_stats
    from repro.experiments.runner import run_scenarios, training_shape_key

    cells = churn_matrix()
    classes = {training_shape_key(s) for s in cells}
    engine_cache_clear()
    t0 = time.perf_counter()
    results = run_scenarios(cells, "training", replicas=3)
    sweep_s = time.perf_counter() - t0
    st = engine_cache_stats()
    assert st.compiles <= len(classes), (st, len(classes))

    by = {}
    for (pname, _, _), group in zip(
            POLICIES, [results[i:i + len(DROPOUTS)]
                       for i in range(0, len(results), len(DROPOUTS))]):
        for rate, r in zip(DROPOUTS, group):
            loss = r.series["loss"].mean(axis=0)
            assert np.isfinite(loss).all(), r.tag
            assert loss[-1] < loss[0], (r.tag, float(loss[0]), float(loss[-1]))
            by[(pname, rate)] = r

    # convergence-speed target: 1.5x the best final loss anywhere in the sweep
    target = 1.5 * min(float(r.series["loss"].mean(axis=0)[-1]) for r in by.values())
    cells_out = [{
        "policy": pname, "dropout": rate, "tag": r.tag,
        "final_loss": float(r.series["loss"].mean(axis=0)[-1]),
        "gbits": r.measured["gbits"],
        "steps_to_target": _steps_to(r.series["loss"].mean(axis=0), target),
    } for (pname, rate), r in by.items()]

    # the headline claim: under 30% dropout the variance-feedback policy
    # beats at least one static operating point on final loss
    adaptive = by[("adaptive_qsgd", 0.3)].series["loss"].mean(axis=0)[-1]
    statics = [by[(p, 0.3)].series["loss"].mean(axis=0)[-1]
               for p in ("static_qsgd4", "static_qsgd16")]
    assert float(adaptive) < max(float(x) for x in statics), (adaptive, statics)

    record = {
        "n_cells": len(cells),
        "n_shape_classes": len(classes),
        "compiles": st.compiles,
        "steps": cells[0].steps,
        "n_workers": cells[0].n_workers,
        "replicas": 3,
        "sweep_wall_clock_s": sweep_s,
        "loss_target": target,
        "adaptive_final_loss_at_30pct": float(adaptive),
        "static_final_losses_at_30pct": [float(x) for x in statics],
        "cells": cells_out,
    }
    rows = [
        Row("churn/engine_sweep", sweep_s * 1e6,
            f"{len(cells)} cells -> {len(classes)} classes, "
            f"{st.compiles} compiles"),
        Row("churn/adaptive_vs_static_30pct", 0.0,
            f"adaptive={float(adaptive):.4g} statics="
            f"{[round(float(x), 4) for x in statics]}"),
    ]
    return record, rows


def _trainer_leg() -> tuple[dict, list[Row]]:
    import jax

    from repro.experiments.trainer_substrate import run_trainer_sweep, trainer_shape_key
    from repro.train.steps import bundle_cache_clear, bundle_cache_stats

    ndev = len(jax.devices())
    if ndev < 2:
        return {"skipped": "needs >=2 devices"}, [
            Row("churn/trainer_sweep", 0.0,
                "skipped: needs >=2 devices (set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=4)")]

    cells = []
    for comp, kw in (("qsgd", {"levels": 16}),
                     ("adaptive_qsgd", {"var_target": 0.5}),
                     ("size_adaptive", {"threshold": 4096})):
        for rate in (0.0, 0.3):
            cells.append(Scenario(
                sync="bsp", n_workers=4, steps=12, lr=0.1, compressor=comp,
                compressor_kwargs=kw, error_feedback=True, churn=True,
                dropout_rate=rate, seed=0))
    classes = {trainer_shape_key(s, data_par=min(s.n_workers, ndev))
               for s in cells}
    bundle_cache_clear()
    t0 = time.perf_counter()
    results, skipped = run_trainer_sweep(cells, n_devices=ndev)
    sweep_s = time.perf_counter() - t0
    assert not skipped, skipped
    st = bundle_cache_stats()
    assert st.builds <= len(classes), (st, len(classes))
    assert st.hits == len(cells) - st.builds, st
    for r in results:
        assert np.isfinite(r.series["loss_full"]).all(), r.tag

    record = {
        "n_cells": len(cells),
        "n_shape_classes": len(classes),
        "builds": st.builds,
        "cache_hits": st.hits,
        "n_devices": ndev,
        "sweep_wall_clock_s": sweep_s,
        "cells": [{"tag": r.tag, "measured": dict(r.measured)} for r in results],
    }
    rows = [Row("churn/trainer_sweep", sweep_s * 1e6,
                f"{len(cells)} cells -> {len(classes)} classes, "
                f"{st.builds} builds ({st.hits} hits)")]
    return record, rows


def _rejoin_engine_leg() -> tuple[dict, list[Row]]:
    """reset vs pull_avg on the scan engine: windowed dropout over local-SGD
    cells — both policies converge, the policy is structural (one compile
    each), and the pull_avg download shows up in the bit ledger."""
    from repro.core.simulate import engine_cache_clear, engine_cache_stats
    from repro.experiments.runner import run_scenarios

    steps = 200
    base = dict(sync="local", local_steps=5, n_workers=8, steps=steps,
                lr=0.05, compressor="qsgd", compressor_kwargs={"levels": 16},
                error_feedback=True, churn=True, dropout_rate=0.3,
                churn_start=steps // 4, churn_end=3 * steps // 4, seed=0)
    cells = [Scenario(**base, rejoin_policy="reset"),
             Scenario(**base, rejoin_policy="pull_avg")]
    engine_cache_clear()
    t0 = time.perf_counter()
    results = run_scenarios(cells, "training", replicas=3)
    sweep_s = time.perf_counter() - t0
    st = engine_cache_stats()
    # rejoin_policy is STRUCTURAL: one compile per policy, none per rate
    assert st.compiles == 2, st

    out = {}
    for r in results:
        loss = r.series["loss"].mean(axis=0)
        assert np.isfinite(loss).all(), r.tag
        assert loss[-1] < loss[0], (r.tag, float(loss[0]), float(loss[-1]))
        out[r.scenario.rejoin_policy] = {
            "tag": r.tag,
            "final_loss": float(loss[-1]),
            "gbits": r.measured["gbits"],
        }
    # the pull_avg download is charged: more bits than the alpha-only reset
    assert out["pull_avg"]["gbits"] > out["reset"]["gbits"], out

    record = {"steps": steps, "dropout": 0.3,
              "window": [steps // 4, 3 * steps // 4],
              "compiles": st.compiles, "sweep_wall_clock_s": sweep_s,
              "policies": out}
    rows = [Row("churn/rejoin_engine", sweep_s * 1e6,
                "reset={:.4g} pull_avg={:.4g} (final loss, 2 compiles)".format(
                    out["reset"]["final_loss"], out["pull_avg"]["final_loss"]))]
    return record, rows


def _rejoin_timeline_leg() -> tuple[dict, list[Row]]:
    """Predicted vs measured resync overhead on the timeline event stream."""
    from repro.experiments.runner import predict, run_scenario

    base = dict(sync="bsp", n_workers=8, steps=120, compute_time=0.01,
                churn=True, dropout_rate=0.2, churn_start=20, churn_end=90,
                seed=0)
    record = {}
    for policy in ("reset", "pull_avg"):
        s = Scenario(**base, rejoin_policy=policy)
        r = run_scenario(s, "timeline")
        p = predict(s, "timeline")
        m = r.measured
        assert m["resync_events"] > 0, policy
        # one sampled stream vs the closed-form expectation: within 2x
        assert 0.5 < p["resync_events"] / m["resync_events"] < 2.0, (p, m)
        record[policy] = {
            "measured": {k: m[k] for k in
                         ("resync_events", "resync_seconds", "resync_bytes")},
            "predicted": {k: p[k] for k in
                          ("resync_events", "resync_seconds", "resync_bytes")},
        }
    assert record["reset"]["measured"]["resync_bytes"] == 0.0
    assert (record["pull_avg"]["measured"]["resync_seconds"]
            > record["reset"]["measured"]["resync_seconds"])

    rows = [Row("churn/rejoin_timeline", 0.0,
                "events measured={:.0f} predicted={:.1f}".format(
                    record["pull_avg"]["measured"]["resync_events"],
                    record["pull_avg"]["predicted"]["resync_events"]))]
    return record, rows


def _rejoin_trainer_leg() -> tuple[dict, list[Row]]:
    """The three formerly-rejected trainer combos under windowed churn."""
    import jax

    from repro.experiments.trainer_substrate import run_trainer_sweep, trainer_shape_key
    from repro.train.steps import bundle_cache_clear, bundle_cache_stats

    ndev = len(jax.devices())
    if ndev < 2:
        return {"skipped": "needs >=2 devices"}, [
            Row("churn/rejoin_trainer", 0.0,
                "skipped: needs >=2 devices (set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=4)")]

    window = dict(churn=True, dropout_rate=0.3, churn_start=2, churn_end=8,
                  seed=0)
    cells = [
        # PowerSGD: masked factor psums (policy has no pull on bsp — reset)
        Scenario(sync="bsp", n_workers=4, steps=12, lr=0.05,
                 compressor="powersgd", compressor_kwargs={"rank": 2},
                 error_feedback=True, **window),
    ]
    for policy in ("reset", "pull_avg"):
        # CHOCO gossip: mirror freeze + rejoin resync channel
        cells.append(Scenario(arch="gossip", gossip_compress="choco",
                              n_workers=4, steps=12, lr=0.05,
                              compressor="qsgd",
                              compressor_kwargs={"levels": 16},
                              rejoin_policy=policy, **window))
        # masked runtime parameter averaging over the local-SGD sync round
        cells.append(Scenario(sync="local", local_steps=2, n_workers=4,
                              steps=12, lr=0.05, compressor="qsgd",
                              compressor_kwargs={"levels": 16},
                              error_feedback=True, rejoin_policy=policy,
                              **window))

    dp = min(4, ndev)
    classes = {trainer_shape_key(s, data_par=dp) for s in cells}
    bundle_cache_clear()
    t0 = time.perf_counter()
    results, skipped = run_trainer_sweep(cells, data_par=dp)
    sweep_s = time.perf_counter() - t0
    assert not skipped, skipped
    st = bundle_cache_stats()
    assert st.builds <= len(classes), (st, len(classes))

    cells_out = []
    for r in results:
        assert np.isfinite(r.series["loss_full"]).all(), r.tag
        m = r.measured
        for key in ("live_fraction", "wire_kb_per_step_alive",
                    "wire_resync_kb_per_step"):
            assert key in m, (r.tag, key)
        cells_out.append({"tag": r.tag, "final_loss": m["final_loss"],
                          "live_fraction": m["live_fraction"],
                          "wire_kb_per_step": m["wire_kb_per_step"],
                          "wire_kb_per_step_alive": m["wire_kb_per_step_alive"],
                          "wire_resync_kb_per_step": m["wire_resync_kb_per_step"]})
    # the dense pull shows on the wire: each pull_avg cell's resync channel
    # books at least as many bytes as its reset twin's
    by_tag = {c["tag"]: c for c in cells_out}
    for pull_tag, c in by_tag.items():
        if "+rejoin=pull_avg" not in pull_tag:
            continue
        reset_tag = pull_tag.replace("+rejoin=pull_avg", "")
        assert c["wire_resync_kb_per_step"] >= \
            by_tag[reset_tag]["wire_resync_kb_per_step"], (pull_tag, by_tag)

    record = {"n_cells": len(cells), "n_shape_classes": len(classes),
              "builds": st.builds, "n_devices": ndev, "data_par": dp,
              "sweep_wall_clock_s": sweep_s, "cells": cells_out}
    rows = [Row("churn/rejoin_trainer", sweep_s * 1e6,
                f"{len(cells)} formerly-rejected cells -> "
                f"{len(classes)} classes, {st.builds} builds")]
    return record, rows


def _integrity_engine_leg() -> tuple[dict, list[Row]]:
    """Gradient-integrity axis on the scan engine: {static qsgd16,
    adaptive_qsgd} x {clean, 10% bitflip, 10% nan}.  Guarded cells stay
    finite and converge, quarantine tallies (worker-rounds, undelivered
    bits, escalations) are booked, and the variance-feedback adaptive
    policy keeps converging under 10% corruption — a quarantined round
    reads as a masked round to its dispersion signal, not as poison."""
    from repro.core.simulate import engine_cache_clear, engine_cache_stats
    from repro.experiments.runner import run_scenarios

    steps = 200
    kinds = ("none", "bitflip", "nan")
    cells, names = [], []
    for pname, comp, kw in (("static_qsgd16", "qsgd", {"levels": 16}),
                            ("adaptive_qsgd", "adaptive_qsgd",
                             {"var_target": 0.5})):
        for kind in kinds:
            rate = 0.1 if kind != "none" else 0.0
            cells.append(Scenario(
                sync="bsp", n_workers=8, steps=steps, lr=0.05,
                compressor=comp, compressor_kwargs=kw, error_feedback=True,
                churn=True, dropout_rate=0.0, corruption_rate=rate,
                corruption_kind=kind, seed=0))
            names.append((pname, kind))
    engine_cache_clear()
    t0 = time.perf_counter()
    results = run_scenarios(cells, "training", replicas=3)
    sweep_s = time.perf_counter() - t0
    st = engine_cache_stats()
    # the corruption KIND is structural, the rate is traced: at most one
    # compile per (policy family, kind)
    assert st.compiles <= len(cells), st

    out = {}
    for (pname, kind), r in zip(names, results):
        loss = r.series["loss"].mean(axis=0)
        assert np.isfinite(loss).all(), r.tag
        assert loss[-1] < loss[0], (r.tag, float(loss[0]), float(loss[-1]))
        entry = {"tag": r.tag, "final_loss": float(loss[-1]),
                 "gbits": r.measured["gbits"]}
        if kind != "none":
            assert r.measured["quarantine_rounds"] > 0, r.tag
            assert r.measured["quarantined_gbits"] > 0, r.tag
            entry.update(quarantine_rounds=r.measured["quarantine_rounds"],
                         quarantined_gbits=r.measured["quarantined_gbits"],
                         escalations=r.measured["escalations"])
        out[f"{pname}/{kind}"] = entry
    # corruption degrades but never wrecks: every guarded cell lands within
    # 2x of its policy's clean twin
    for pname in ("static_qsgd16", "adaptive_qsgd"):
        clean = out[f"{pname}/none"]["final_loss"]
        for kind in kinds[1:]:
            hot = out[f"{pname}/{kind}"]["final_loss"]
            assert hot <= 2.0 * clean + 1e-6, (pname, kind, hot, clean)

    record = {"steps": steps, "corruption_rate": 0.1,
              "compiles": st.compiles, "sweep_wall_clock_s": sweep_s,
              "cells": out}
    rows = [Row("churn/integrity_engine", sweep_s * 1e6,
                "adaptive/bitflip quarantined {:.0f} rounds "
                "({:.3g} gbits undelivered)".format(
                    out["adaptive_qsgd/bitflip"]["quarantine_rounds"],
                    out["adaptive_qsgd/bitflip"]["quarantined_gbits"]))]
    return record, rows


def _integrity_timeline_leg() -> tuple[dict, list[Row]]:
    """Predicted vs measured quarantined wire on the timeline stream."""
    from repro.experiments.runner import predict, run_scenario

    s = Scenario(sync="bsp", n_workers=8, steps=120, compute_time=0.01,
                 corruption_rate=0.1, corruption_kind="bitflip",
                 quarantine_limit=3, seed=0)
    r = run_scenario(s, "timeline")
    p = predict(s, "timeline")
    m = r.measured
    assert m["quarantine_events"] > 0
    assert m["quarantined_bytes"] > 0
    assert 0.5 < p["quarantine_events"] / m["quarantine_events"] < 2.0, (p, m)
    record = {
        "measured": {k: m[k] for k in ("quarantine_events",
                                       "quarantined_bytes",
                                       "escalation_events")},
        "predicted": {k: p[k] for k in ("quarantine_events",
                                        "quarantined_bytes")},
    }
    rows = [Row("churn/integrity_timeline", 0.0,
                "quarantined wire measured={:.0f} predicted={:.1f} events".format(
                    m["quarantine_events"], p["quarantine_events"]))]
    return record, rows


def _integrity_trainer_leg() -> tuple[dict, list[Row]]:
    """Hot corruption on the real mesh: measured quarantine accounting next
    to the closed-form prediction."""
    import jax

    from repro.experiments.trainer_substrate import run_trainer_scenario

    ndev = len(jax.devices())
    if ndev < 2:
        return {"skipped": "needs >=2 devices"}, [
            Row("churn/integrity_trainer", 0.0,
                "skipped: needs >=2 devices (set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=4)")]

    dp = min(4, ndev)
    s = Scenario(sync="bsp", n_workers=dp, steps=12, lr=0.05,
                 compressor="qsgd", compressor_kwargs={"levels": 16},
                 error_feedback=True, corruption_rate=0.1,
                 corruption_kind="bitflip", seed=0)
    t0 = time.perf_counter()
    r = run_trainer_scenario(s, data_par=dp)
    sweep_s = time.perf_counter() - t0
    assert np.isfinite(r.series["loss_full"]).all()
    m, p = r.measured, r.predicted
    record = {
        "n_devices": ndev, "data_par": dp, "sweep_wall_clock_s": sweep_s,
        "tag": r.tag,
        "measured": {k: m[k] for k in
                     ("quarantine_rounds", "escalations",
                      "quarantine_fraction", "wire_kb_per_step_quarantined")},
        "predicted": {k: p[k] for k in
                      ("quarantine_fraction",
                       "wire_kb_per_step_quarantined")},
    }
    rows = [Row("churn/integrity_trainer", sweep_s * 1e6,
                "quarantine_fraction measured={:.3f} predicted<={:.3f}".format(
                    m["quarantine_fraction"], p["quarantine_fraction"]))]
    return record, rows


def run() -> list[Row]:
    engine_rec, rows = _engine_leg()
    trainer_rec, trows = _trainer_leg()
    rows += trows
    rj_engine, rrows = _rejoin_engine_leg()
    rows += rrows
    rj_timeline, trows2 = _rejoin_timeline_leg()
    rows += trows2
    rj_trainer, trows3 = _rejoin_trainer_leg()
    rows += trows3
    it_engine, irows = _integrity_engine_leg()
    rows += irows
    it_timeline, irows2 = _integrity_timeline_leg()
    rows += irows2
    it_trainer, irows3 = _integrity_trainer_leg()
    rows += irows3
    with open(BENCH_PATH, "w") as f:
        json.dump({"engine": engine_rec, "trainer": trainer_rec,
                   "rejoin": {"engine": rj_engine, "timeline": rj_timeline,
                              "trainer": rj_trainer},
                   "integrity": {"engine": it_engine,
                                 "timeline": it_timeline,
                                 "trainer": it_trainer}}, f, indent=2)
    rows.append(Row("churn/claims_validated", 0.0, True))
    return rows
