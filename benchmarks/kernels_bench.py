"""Pallas kernel micro-benchmarks (interpret mode on CPU — correctness-level
timings; HBM-traffic derivation is the TPU-relevant 'derived' column).

The fused EF+QSGD kernel's value is the traffic model:
    unfused: 5 reads + 3 writes of 4N bytes  (a=e+g; Q; e'=a-deq)
    fused:   3 reads + 1.25 writes
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.kernels import ops

N = 262_144  # modest for interpret-mode timing


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.key(0)
    x = jax.random.normal(key, (N,)) * 0.1
    e = jax.random.normal(jax.random.fold_in(key, 1), (N,)) * 0.05
    u = jax.random.uniform(jax.random.fold_in(key, 2), (N,))

    us = time_fn(lambda: ops.qsgd_quantize(x, u, levels=16))
    rows.append(Row("kernels/qsgd", us, f"{4*N/1e6:.1f}MB_read_1.0MB_write"))
    us = time_fn(lambda: ops.qsgd_ef_fused(x, e, u, levels=16))
    unfused_traffic = 8 * 4 * N
    fused_traffic = (3 * 4 + 1 + 4) * N
    rows.append(Row("kernels/qsgd_ef_fused", us,
                    f"hbm_traffic_{unfused_traffic/fused_traffic:.2f}x_less"))
    us = time_fn(lambda: ops.terngrad_quantize(x, u))
    rows.append(Row("kernels/terngrad", us, "int8_payload"))
    us = time_fn(lambda: ops.sign_pack(x))
    rows.append(Row("kernels/sign_pack", us, "32x_wire"))
    us = time_fn(lambda: ops.threshold_sparsify(x, 0.05))
    rows.append(Row("kernels/threshold", us, "fused_mask+count"))

    B, S, H, hd = 1, 256, 4, 64
    r, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, hd)) * 0.3 for i in range(3, 6))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 6), (B, S, H, hd))) * 0.5 + 0.4
    uu = jax.random.normal(jax.random.fold_in(key, 7), (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd))
    us = time_fn(lambda: ops.wkv6(r, k, v, w, uu, s0, chunk=64), reps=3)
    flops = 4 * B * S * H * hd * hd * 2
    rows.append(Row("kernels/wkv6_chunked", us, f"{flops/1e6:.0f}MFLOP_vmem_resident_state"))
    return rows
