"""Pallas kernel micro-benchmarks + fused-vs-composed compressed-collective
roofline rows.  Writes ``BENCH_kernels.json`` at the repo root.

Interpret-mode caveat: off-TPU every kernel here runs with ``interpret=True``
(`repro.kernels.ops._interpret`), so the ``us_per_call`` column is a
correctness-level CPU timing — the Pallas interpreter evaluates kernel bodies
with jnp ops, and a fused kernel can even time *slower* than the composed jnp
path it replaces.  The TPU-relevant figure is the ``derived`` HBM-traffic
model: bytes the fused single-pass kernel moves vs the composed multi-pass
path (which round-trips every intermediate through HBM).  Both numbers are
recorded; rank kernels by traffic, not by interpret-mode wall time.

The qsgd resweep row addresses the traced-knob discipline end-to-end: it
times levels 4/8/16 through ONE compiled executable and asserts the jit
cache did not grow (``0 recompiles`` — levels is a traced value, not a jit
specialization constant).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.kernels import ops

N = 262_144  # modest for interpret-mode timing
W = 8        # gathered worker count for the collective-reduce rows

BENCH_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_kernels.json")


def _traffic(name: str, fused_bytes: float, composed_bytes: float) -> str:
    return f"hbm_{composed_bytes / fused_bytes:.1f}x_less_than_composed"


def run() -> list[Row]:
    rows: list[Row] = []
    record: dict = {"n": N, "workers": W, "interpret_mode": True,
                    "families": {}}
    key = jax.random.key(0)
    x = jax.random.normal(key, (N,)) * 0.1
    e = jax.random.normal(jax.random.fold_in(key, 1), (N,)) * 0.05
    u = jax.random.uniform(jax.random.fold_in(key, 2), (N,))

    # ---- single-kernel rows (continuity with earlier BENCH history) ----
    us = time_fn(lambda: ops.qsgd_quantize(x, u, levels=16))
    rows.append(Row("kernels/qsgd", us, f"{4*N/1e6:.1f}MB_read_1.0MB_write"))
    us = time_fn(lambda: ops.terngrad_quantize(x, u))
    rows.append(Row("kernels/terngrad", us, "int8_payload"))
    us = time_fn(lambda: ops.sign_pack(x))
    rows.append(Row("kernels/sign_pack", us, "32x_wire"))
    us = time_fn(lambda: ops.threshold_sparsify(x, 0.05))
    rows.append(Row("kernels/threshold", us, "fused_mask+count"))

    # ---- fused vs composed: sign pack -> vote (majority collective) ----
    # fused: the wire carries the 1-bit bitmap; sign_vote decodes and
    # weight-accumulates W payloads in one pass (no unpacked intermediate).
    # composed: unpack each worker's payload to f32 signs, stack, reduce.
    packed_w = [ops.sign_pack(jax.random.normal(jax.random.fold_in(key, 10 + w),
                                                (N,))) for w in range(W)]
    packed = jnp.stack(packed_w)                      # the gathered wire tensor
    weights = jnp.ones((W,), jnp.float32)
    fused_sign = jax.jit(lambda p, wt: jnp.sign(ops.sign_vote(p, wt, n=N)))
    composed_sign = jax.jit(lambda p, wt: jnp.sign(
        sum(wt[w] * ops.sign_unpack(p[w], N) for w in range(W))))
    assert bool(jnp.array_equal(fused_sign(packed, weights),
                                composed_sign(packed, weights)))
    us_f = time_fn(fused_sign, packed, weights)
    us_c = time_fn(composed_sign, packed, weights)
    # fused reads W*N/8 packed bytes, writes 4N f32 votes; composed also
    # round-trips W unpacked f32 tensors (write + re-read = 8*4N each)
    tf, tc = N * (W / 8 + 4), N * (W / 8 + 8 * W + 4)
    rows.append(Row("kernels/sign_vote_fused", us_f, _traffic("sign", tf, tc)))
    rows.append(Row("kernels/sign_vote_composed", us_c,
                    f"materializes_{W}x{4*N/1e6:.1f}MB_unpacked"))
    record["families"]["sign_vote"] = {
        "fused_us": us_f, "composed_us": us_c,
        "fused_bytes": tf, "composed_bytes": tc, "bitwise_equal": True}

    # ---- fused vs composed: ternary 2-bit pack -> accumulate ----
    tern = jnp.sign(jax.random.normal(jax.random.fold_in(key, 30),
                                      (N,))).astype(jnp.int8) * \
        (jax.random.uniform(jax.random.fold_in(key, 31), (N,)) < 0.5)
    tpacked = jnp.stack([ops.tern_pack(tern) for _ in range(W)])
    scales = jnp.linspace(0.5, 1.5, W)
    us_pack = time_fn(lambda: ops.tern_pack(tern))
    rows.append(Row("kernels/tern_pack", us_pack, "16x_wire_vs_f32"))
    fused_tern = jax.jit(lambda p, s: ops.tern_acc(p, s, n=N))
    composed_tern = jax.jit(lambda t, s: sum(
        s[w] * t.astype(jnp.float32) for w in range(W)))
    us_f = time_fn(fused_tern, tpacked, scales)
    us_c = time_fn(composed_tern, tern, scales)
    # fused reads W*N/4 packed; composed reads the W*N int8 decode + the
    # same f32 round-trips the unfused reduce chain implies
    tf, tc = N * (W / 4 + 4), N * (W + 8 * W + 4)
    rows.append(Row("kernels/tern_acc_fused", us_f, _traffic("tern", tf, tc)))
    rows.append(Row("kernels/tern_acc_composed", us_c, "int8_decode_per_worker"))
    record["families"]["tern_acc"] = {
        "fused_us": us_f, "composed_us": us_c,
        "fused_bytes": tf, "composed_bytes": tc}

    # ---- fused vs composed: int8 widening weighted sum (qsgd wire) ----
    codes = jnp.stack([ops.qsgd_quantize(
        jax.random.normal(jax.random.fold_in(key, 40 + w), (N,)), u,
        levels=16)[0] for w in range(W)])
    dec_w = jnp.linspace(0.01, 0.02, W)
    fused_i8 = jax.jit(lambda c, wt: ops.int8_weighted_sum(c, wt))
    composed_i8 = jax.jit(
        lambda c, wt: (c.astype(jnp.float32) * wt[:, None]).sum(axis=0))
    us_f = time_fn(fused_i8, codes, dec_w)
    us_c = time_fn(composed_i8, codes, dec_w)
    tf, tc = N * (W + 4), N * (W + 8 * W + 4)
    rows.append(Row("kernels/int8_acc_fused", us_f, _traffic("int8", tf, tc)))
    rows.append(Row("kernels/int8_acc_composed", us_c,
                    f"widens_to_{W}x{4*N/1e6:.1f}MB_f32"))
    record["families"]["int8_acc"] = {
        "fused_us": us_f, "composed_us": us_c,
        "fused_bytes": tf, "composed_bytes": tc}

    # ---- fused vs composed: EF + quantize in the bucketized pipeline ----
    fused_ef = jax.jit(lambda g, ee, uu: ops.qsgd_ef_fused(g, ee, uu, levels=16))
    def _composed_ef(g, ee, uu):
        a = ee * 1.0 + g                       # pass 1: accumulate EF
        codes, norm = ops.qsgd_quantize(a, uu, levels=16)   # pass 2
        e_new = a - ops.qsgd_dequantize(codes, norm, levels=16)  # pass 3
        return codes, norm, e_new
    composed_ef = jax.jit(_composed_ef)
    us_f = time_fn(fused_ef, x, e, u)
    us_c = time_fn(composed_ef, x, e, u)
    tf, tc = (3 * 4 + 1 + 4) * N, 8 * 4 * N
    rows.append(Row("kernels/qsgd_ef_fused", us_f, _traffic("qsgd_ef", tf, tc)))
    rows.append(Row("kernels/qsgd_ef_composed", us_c, "3_passes_over_4N"))
    record["families"]["qsgd_ef"] = {
        "fused_us": us_f, "composed_us": us_c,
        "fused_bytes": tf, "composed_bytes": tc}

    # ---- traced-knob resweep: levels is a VALUE, not a compile constant ----
    ops.qsgd_quantize(x, u, levels=16)  # ensure compiled
    before = ops.qsgd_quantize._cache_size()
    sweep_us = {lv: time_fn(lambda lv=lv: ops.qsgd_quantize(x, u, levels=lv))
                for lv in (4, 8, 16)}
    recompiles = ops.qsgd_quantize._cache_size() - before
    assert recompiles == 0, f"levels resweep recompiled {recompiles}x"
    rows.append(Row("kernels/qsgd_levels_resweep",
                    sum(sweep_us.values()) / len(sweep_us),
                    f"levels=4,8,16_{recompiles}_recompiles"))
    record["qsgd_levels_resweep"] = {
        "us_per_level": {str(k): v for k, v in sweep_us.items()},
        "recompiles": recompiles}

    # ---- wkv6 (continuity) ----
    B, S, H, hd = 1, 256, 4, 64
    r, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, hd)) * 0.3
               for i in range(3, 6))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 6),
                                         (B, S, H, hd))) * 0.5 + 0.4
    uu = jax.random.normal(jax.random.fold_in(key, 7), (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd))
    us = time_fn(lambda: ops.wkv6(r, k, v, w, uu, s0, chunk=64), reps=3)
    flops = 4 * B * S * H * hd * hd * 2
    rows.append(Row("kernels/wkv6_chunked", us,
                    f"{flops/1e6:.0f}MFLOP_vmem_resident_state"))

    record["rows"] = [{"name": r.name, "us_per_call": r.us_per_call,
                       "derived": str(r.derived)} for r in rows]
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)
    rows.append(Row("kernels/claims_validated", 0.0, True))
    return rows
