"""Paper Table IV: per-worker communication cost of every
(architecture x sync x compression) cell — the analytic Big-O rows come
from the engine's cost-model predictions; the *measured* payload bytes come
from the real compressors' wire formats."""

from __future__ import annotations

import jax

from benchmarks.common import Row
from repro.core.compression import get_compressor
from repro.experiments import Scenario
from repro.experiments.runner import estimated_wire_bytes, rounds_per_iter

N = 25_000_000  # 25M-parameter model (the survey's running example scale)


def run() -> list[Row]:
    rows: list[Row] = []
    dense_bytes = 4.0 * N
    for sync, H in (("bsp", 1), ("local_sgd_H8", 8)):
        for comp, kw in (
            (None, {}),
            ("qsgd", {"levels": 16}),
            ("topk", {"ratio": 0.001}),
        ):
            s = Scenario(
                sync="local" if H > 1 else "bsp", local_steps=max(H, 2),
                compressor=comp, compressor_kwargs=kw, msg_bytes=dense_bytes,
            )
            per_iter = estimated_wire_bytes(s) * rounds_per_iter(s)
            name = {None: "none", "qsgd": "quant", "topk": "spars"}[comp]
            rows.append(
                Row(f"tableIV/{sync}/{name}", 0.0,
                    f"{per_iter/1e6:.2f}MB_per_iter_x{dense_bytes/per_iter:.0f}")
            )
    # measured payload bytes of the actual wire formats (1M-element bucket)
    n = 1_000_000
    x = jax.random.normal(jax.random.key(0), (n,))
    for name, kw in (
        ("qsgd", {"levels": 16}), ("terngrad", {}), ("signsgd", {}),
        ("signsgd_packed", {}), ("onebit", {}), ("natural", {}),
        ("topk", {"ratio": 0.001}), ("gtopk", {"ratio": 0.001}),
        ("stc", {"ratio": 0.001}), ("sbc", {"ratio": 0.001}),
    ):
        comp = get_compressor(name, **kw)
        c = comp.compress(jax.random.key(1), x)
        ratio = 4.0 * n / c.payload_bytes()
        rows.append(Row(f"tableIV/payload/{name}", 0.0, f"{c.payload_bytes()}B_x{ratio:.0f}"))
    return rows
