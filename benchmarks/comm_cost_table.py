"""Paper Table IV: per-worker communication cost of every
(architecture x sync x compression) cell, both analytic Big-O instantiation
and *measured* payload bytes from the real compressors."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core.compression import get_compressor
from repro.core.costmodel import upload_bits

N = 25_000_000  # 25M-parameter model (the survey's running example scale)


def run() -> list[Row]:
    rows: list[Row] = []
    dense_bits = 32.0 * N
    for sync, T, T_comm in (("bsp", 1, 1), ("local_sgd_H8", 8, 8)):
        for comp, kw in (
            ("none", {}),
            ("quant", {"levels": 16}),
            ("spars", {"ratio": 0.001}),
        ):
            bits = upload_bits(comp, N, T=T, T_comm=T_comm, **kw)
            per_iter = bits / T
            rows.append(
                Row(f"tableIV/{sync}/{comp}", 0.0,
                    f"{per_iter/8/1e6:.2f}MB_per_iter_x{dense_bits/per_iter:.0f}")
            )
    # measured payload bytes of the actual wire formats (1M-element bucket)
    n = 1_000_000
    x = jax.random.normal(jax.random.key(0), (n,))
    for name, kw in (
        ("qsgd", {"levels": 16}), ("terngrad", {}), ("signsgd", {}),
        ("signsgd_packed", {}), ("onebit", {}), ("natural", {}),
        ("topk", {"ratio": 0.001}), ("gtopk", {"ratio": 0.001}),
        ("stc", {"ratio": 0.001}), ("sbc", {"ratio": 0.001}),
    ):
        comp = get_compressor(name, **kw)
        c = comp.compress(jax.random.key(1), x)
        ratio = 4.0 * n / c.payload_bytes()
        rows.append(Row(f"tableIV/payload/{name}", 0.0, f"{c.payload_bytes()}B_x{ratio:.0f}"))
    return rows
