"""Paper §VII made executable: the overlap axis on the REAL mesh trainer.

Sequential vs microbatch-pipelined bucketized aggregation x bucket sizes x
{none, qsgd, topk} compressors on a forced-host multi-device mesh — the
acceptance sweep behind ``BENCH_overlap.json`` at the repo root.  Per cell
it records the measured per-step wall-clock, the wire bytes, and (for
pipelined cells) the measured overlap saving vs the sequential twin next to
the ``simulate_schedule`` prediction (predicted-vs-measured, the Shi et al.
methodology).  Asserts:

* pipelined loss trajectories are unchanged-or-equal: every pipelined cell's
  final loss stays within a few percent of its sequential twin, and the
  staleness-1 degradation matches the simulator's ``ssp(s=1)`` reference
  band (both are ~1.0x the synchronous final loss);
* pipelined cells are bit-reproducible across bundle-cache hits (a re-run
  through the shared compiled bundle reproduces the loss series exactly);
* the bundle registry builds at most one bundle per shape class — cells
  differing only in traced overlap/compressor knobs reuse compiles.

NOTE: a measured wall-clock IMPROVEMENT is *not* asserted — on forced host
devices XLA's latency-hiding scheduler has no real NIC to overlap, so the
pipelined path usually pays for its extra collective rounds; the record
exists to track the saving on real multi-chip meshes.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import Row
from repro.experiments import Scenario

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "BENCH_overlap.json")

#: compressor axis: dense, quantized (unbiased, EF-free — per-microbatch EF
#: compounds quantization noise, a real finding the sweep records), sparse+EF
FAMILIES = ((None, {}, False),
            ("qsgd", {"levels": 16}, False),
            ("topk", {"ratio": 0.05}, True))


def overlap_matrix(*, steps: int = 16, n_workers: int = 2, microbatch: int = 4,
                   seed: int = 0) -> list[Scenario]:
    """3 compressor families x 2 bucket granularities x {sequential,
    pipelined} = 12 cells (12 shape classes), plus 2 knob-traced siblings of
    one pipelined class (qsgd levels, stale_scale) that must be bundle-cache
    hits — 14 cells, 12 builds."""
    cells = []
    for comp, kw, ef in FAMILIES:
        for bucket in (0.0, 0.25e6):
            for overlap in ("sequential", "pipelined"):
                cells.append(Scenario(
                    sync="bsp", n_workers=n_workers, steps=steps, lr=0.05,
                    compressor=comp, compressor_kwargs=kw, error_feedback=ef,
                    schedule=("mgwfbp" if bucket else "wfbp"),
                    bucket_bytes=bucket, overlap=overlap,
                    microbatch=microbatch, seed=seed))
    sib = next(c for c in cells
               if c.overlap == "pipelined" and c.compressor == "qsgd"
               and c.bucket_bytes == 0)
    cells.append(sib.replace(compressor_kwargs={"levels": 8}))
    cells.append(sib.replace(stale_scale=0.5))
    return cells


def _staleness_reference() -> dict:
    """The simulator's ssp(s=1) convergence reference: staleness 1 leaves
    the final loss within a whisker of the synchronous trajectory."""
    from repro.core.simulate import SimCfg, simulate_training_batch

    bsp = simulate_training_batch(SimCfg(n_workers=8, sync="bsp", steps=200,
                                         lr=0.05, seed=0))[0]
    ssp = simulate_training_batch(SimCfg(n_workers=8, sync="ssp", staleness=1,
                                         steps=200, lr=0.05, seed=0))[0]
    return {
        "sim_bsp_final_loss": float(bsp["loss"][-1]),
        "sim_ssp1_final_loss": float(ssp["loss"][-1]),
        "sim_ssp1_ratio": float(ssp["loss"][-1] / bsp["loss"][-1]),
    }


def run() -> list[Row]:
    from repro.experiments.trainer_substrate import (
        _overlap_twin,
        run_trainer_scenario,
        run_trainer_sweep,
        trainer_shape_key,
    )
    from repro.train.steps import bundle_cache_clear, bundle_cache_stats

    ndev = len(jax.devices())
    if ndev < 2:
        return [Row("overlap/sweep", 0.0,
                    "skipped: needs >=2 devices (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=4)")]

    cells = overlap_matrix()
    classes = {trainer_shape_key(s, data_par=min(s.n_workers, ndev))
               for s in cells}
    bundle_cache_clear()
    t0 = time.perf_counter()
    results, skipped = run_trainer_sweep(cells, n_devices=ndev)
    sweep_s = time.perf_counter() - t0
    assert not skipped, skipped
    st = bundle_cache_stats()
    assert st.builds <= len(classes), (st, len(classes))
    assert st.hits == len(cells) - st.builds, st

    by_cell = {r.scenario: r for r in results}
    pair_rows = []
    worst_ratio = 0.0
    for r in results:
        s = r.scenario
        if s.overlap != "pipelined":
            continue
        twin = by_cell.get(_overlap_twin(s))
        if twin is None:
            continue
        ratio = r.measured["final_loss"] / twin.measured["final_loss"]
        worst_ratio = max(worst_ratio, ratio)
        pair_rows.append({
            "tag": r.tag,
            "sequential_tag": twin.tag,
            "loss_ratio_vs_sequential": ratio,
            "measured_overlap_saving_s": r.measured.get("overlap_saving_s"),
            "predicted_overlap_saving_s": r.predicted.get("overlap_saving_s"),
        })

    # unchanged-or-equal trajectories: staleness-1 costs at most a few
    # percent of final loss, the same band the ssp(s=1) simulator sits in
    ref = _staleness_reference()
    assert ref["sim_ssp1_ratio"] < 1.05, ref
    assert worst_ratio < 1.05, (worst_ratio, pair_rows)

    # bit-reproducibility across bundle-cache hits: a re-run of a pipelined
    # cell through the (now cached) compiled bundle is exact
    repro_cell = next(s for s in cells
                      if s.overlap == "pipelined" and s.compressor is None)
    again = run_trainer_scenario(repro_cell, data_par=min(repro_cell.n_workers, ndev))
    np.testing.assert_array_equal(
        again.series["loss_full"], by_cell[repro_cell].series["loss_full"],
        err_msg="pipelined cell not bit-reproducible across bundle-cache hits")

    record = {
        "n_cells": len(cells),
        "n_shape_classes": len(classes),
        "steps": cells[0].steps,
        "microbatch": cells[0].microbatch,
        "n_devices": ndev,
        "builds": st.builds,
        "cache_hits": st.hits,
        "sweep_wall_clock_s": sweep_s,
        "worst_pipelined_loss_ratio": worst_ratio,
        "staleness_reference": ref,
        "pairs": pair_rows,
        "cells": [{
            "tag": r.tag,
            "measured": dict(r.measured),
            "predicted": dict(r.predicted),
        } for r in results],
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)

    return [
        Row("overlap/sweep", sweep_s * 1e6,
            f"{len(cells)} cells -> {len(classes)} classes, {st.builds} builds "
            f"({st.hits} hits)"),
        Row("overlap/loss_ratio", 0.0,
            f"worst pipelined/sequential={worst_ratio:.4f} "
            f"(sim ssp1 ref {ref['sim_ssp1_ratio']:.4f})"),
        Row("overlap/claims_validated", 0.0, True),
    ]
