"""Paper Table III: all-reduce algorithm costs (alpha-beta model) + measured
manual-schedule (ring/RHD) arithmetic throughput on host.

Validates the table's structural claims: ring is bandwidth-optimal (its
bandwidth term 2N(n-1)/n beats trees' 2N log n for large N), trees win the
latency term at scale, double-binary-tree achieves both.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.core.costmodel import TABLE_III_ALGS, Link, allreduce_cost


def run() -> list[Row]:
    rows: list[Row] = []
    link = Link(alpha=1e-5, beta=1 / 50e9)
    for n in (16, 256, 512):
        for nbytes, tag in ((4 * 1024, "4KiB"), (4 * 25_000_000, "100MB")):
            costs = {alg: allreduce_cost(alg, n, nbytes, link) for alg in TABLE_III_ALGS}
            best = min(costs, key=costs.get)
            for alg, c in costs.items():
                rows.append(Row(f"tableIII/{alg}/n{n}/{tag}", 0.0, f"{c*1e6:.1f}us"))
            rows.append(Row(f"tableIII/best/n{n}/{tag}", 0.0, best))
    # structural checks (the paper's qualitative statements)
    big, small = 4 * 25_000_000, 4 * 1024
    assert allreduce_cost("ring", 256, big, link) < allreduce_cost("binary_tree", 256, big, link)
    assert allreduce_cost("double_binary_tree", 512, small, link) < allreduce_cost("ring", 512, small, link)
    rows.append(Row("tableIII/claims_validated", 0.0, True))
    return rows
