"""Paper §VII: scheduling of communication and computing — iteration time
under sequential / WFBP / MG-WFBP schedules for a ResNet-50-like and a
transformer-like layer profile; bucket-size sweep (MG-WFBP's knob) —
declared as scenarios on the engine's schedule substrate."""

from __future__ import annotations

from benchmarks.common import Row
from repro.experiments import Scenario, run_scenario

LINK = dict(alpha=2e-4, beta=1 / 10e9)


def run() -> list[Row]:
    rows: list[Row] = []
    for profile in ("resnet50", "transformer32"):
        base = None
        times = {}
        for mode, bucket in (("sequential", 0), ("wfbp", 0), ("mgwfbp", 8e6), ("mgwfbp", 64e6)):
            s = Scenario(schedule=mode, bucket_bytes=bucket, layer_profile=profile,
                         n_workers=64, **LINK)
            res = run_scenario(s, "schedule")
            m = res.measured
            times[(mode, bucket)] = m["iter_time"]
            tag = mode if mode != "mgwfbp" else f"mgwfbp_{int(bucket/1e6)}MB"
            if base is None:
                base = m["iter_time"]
            rows.append(Row(
                f"schedule/{profile}/{tag}", 0.0,
                f"iter={m['iter_time']*1e3:.2f}ms msgs={int(m['n_messages'])} "
                f"speedup={base/m['iter_time']:.2f}x "
                f"(pred no-overlap {res.predicted['no_overlap_time']*1e3:.2f}ms)",
            ))
        assert times[("wfbp", 0)] <= times[("sequential", 0)] + 1e-9
        assert times[("mgwfbp", 8e6)] <= times[("wfbp", 0)] + 1e-9
    rows.append(Row("schedule/claims_validated", 0.0, True))
    return rows
