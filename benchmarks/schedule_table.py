"""Paper §VII: scheduling of communication and computing — iteration time
under sequential / WFBP / MG-WFBP / pipelined (double-buffered staleness-1,
the schedule the mesh trainer executes) for a ResNet-50-like and a
transformer-like layer profile; bucket-size sweep (MG-WFBP's knob) —
declared as scenarios on the engine's schedule substrate."""

from __future__ import annotations

from benchmarks.common import Row
from repro.experiments import Scenario, run_scenario

LINK = dict(alpha=2e-4, beta=1 / 10e9)


def run() -> list[Row]:
    rows: list[Row] = []
    for profile in ("resnet50", "transformer32"):
        base = None
        times = {}
        saving = {}
        grid = (("sequential", 0, 1), ("wfbp", 0, 1), ("mgwfbp", 8e6, 1),
                ("mgwfbp", 64e6, 1), ("pipelined", 8e6, 0), ("pipelined", 8e6, 1))
        for mode, bucket, stale in grid:
            s = Scenario(schedule=mode, bucket_bytes=bucket, layer_profile=profile,
                         n_workers=64, overlap_staleness=stale, **LINK)
            res = run_scenario(s, "schedule")
            m = res.measured
            times[(mode, bucket, stale)] = m["iter_time"]
            saving[(mode, bucket, stale)] = m["overlap_saving"]
            tag = mode if bucket == 0 else f"{mode}_{int(bucket/1e6)}MB"
            if mode == "pipelined":
                tag += f"_s{stale}"
            if base is None:
                base = m["iter_time"]
            rows.append(Row(
                f"schedule/{profile}/{tag}", 0.0,
                f"iter={m['iter_time']*1e3:.2f}ms msgs={int(m['n_messages'])} "
                f"speedup={base/m['iter_time']:.2f}x "
                f"saving={m['overlap_saving']*1e3:.2f}ms "
                f"(pred no-overlap {res.predicted['no_overlap_time']*1e3:.2f}ms)",
            ))
            # overlap_saving is consistently no_overlap - iter_time
            assert abs((m["bwd_time"] + m["total_comm_time"] - m["iter_time"])
                       - m["overlap_saving"]) < 1e-12
        assert times[("wfbp", 0, 1)] <= times[("sequential", 0, 1)] + 1e-9
        assert times[("mgwfbp", 8e6, 1)] <= times[("wfbp", 0, 1)] + 1e-9
        # staleness-1 pipelining dominates every producer-ordered schedule
        # (messages start at t=0) and its saving caps at min(bwd, comm)
        assert times[("pipelined", 8e6, 1)] <= times[("mgwfbp", 8e6, 1)] + 1e-9
        assert times[("pipelined", 8e6, 1)] <= times[("pipelined", 8e6, 0)] + 1e-9
        assert saving[("pipelined", 8e6, 1)] >= saving[("mgwfbp", 8e6, 1)] - 1e-9
        assert abs(saving[("sequential", 0, 1)]) < 1e-12
    rows.append(Row("schedule/claims_validated", 0.0, True))
    return rows
