"""Paper §VII: scheduling of communication and computing — iteration time
under sequential / WFBP / MG-WFBP schedules for a ResNet-50-like and a
transformer-like layer profile; bucket-size sweep (MG-WFBP's knob)."""

from __future__ import annotations

from benchmarks.common import Row
from repro.core.costmodel import Link
from repro.core.schedule import LayerSpec, simulate_schedule


def _resnet_like():
    # 161 gradient tensors, mostly small (the MG-WFBP motivation)
    layers = []
    for i in range(160):
        layers.append(LayerSpec(f"conv{i}", grad_bytes=25.5e6 * 4 / 160, backward_time=5e-3 / 160))
    layers.append(LayerSpec("fc", grad_bytes=8e6, backward_time=5e-4))
    return layers


def _transformer_like():
    return [LayerSpec(f"block{i}", grad_bytes=12 * 4096 * 4096 * 2 / 1, backward_time=3e-3)
            for i in range(32)]


def run() -> list[Row]:
    rows: list[Row] = []
    link = Link(alpha=2e-4, beta=1 / 10e9)
    for net, layers in (("resnet50", _resnet_like()), ("transformer32", _transformer_like())):
        base = None
        for mode, bucket in (("sequential", 0), ("wfbp", 0), ("mgwfbp", 8e6), ("mgwfbp", 64e6)):
            r = simulate_schedule(layers, n_workers=64, link=link, alg="ring",
                                  mode=mode, bucket_bytes=bucket)
            tag = mode if mode != "mgwfbp" else f"mgwfbp_{int(bucket/1e6)}MB"
            if base is None:
                base = r["iter_time"]
            rows.append(Row(
                f"schedule/{net}/{tag}", 0.0,
                f"iter={r['iter_time']*1e3:.2f}ms msgs={r['n_messages']} "
                f"speedup={base/r['iter_time']:.2f}x",
            ))
        seq = simulate_schedule(layers, n_workers=64, link=link, alg="ring", mode="sequential")
        wfbp = simulate_schedule(layers, n_workers=64, link=link, alg="ring", mode="wfbp")
        mg = simulate_schedule(layers, n_workers=64, link=link, alg="ring", mode="mgwfbp", bucket_bytes=8e6)
        assert wfbp["iter_time"] <= seq["iter_time"] + 1e-9
        assert mg["iter_time"] <= wfbp["iter_time"] + 1e-9
    rows.append(Row("schedule/claims_validated", 0.0, True))
    return rows
