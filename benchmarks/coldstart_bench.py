"""Cold-start: the persistent compilation cache + calibration acceptance
bench (BENCH_coldstart.json).

Every number that matters here is a COLD-PROCESS number, so each leg runs in
a subprocess with its own interpreter, its own forced device topology, and a
shared on-disk cache directory:

* **cold-process / cold-cache** — fresh interpreter, empty cache dir: the
  full XLA compile bill every process used to pay;
* **cold-process / warm-cache** — fresh interpreter, the SAME cache dir: jax
  deserializes the executables some previous process compiled (the repo
  manifest confirms 0 persistent misses);
* **warm-process** — the second sweep inside one process: the in-memory
  registry bound (engine program cache / bundle registry), unchanged by
  this PR and reported for scale.

Legs run for both compilation layers: the engine 90-cell sweep
(``sweep_matrix_45`` x 2 problem seeds) and the 16-cell trainer matrix
(``trainer_matrix_16`` on 4 forced host devices).  Asserts the acceptance
criterion: warm-disk-cache cold-process trainer sweep >= 3x faster than
cold-cache.

The calibration leg then fits this machine's profile
(:mod:`repro.core.calibrate`: psum alpha-beta ladder, launch overhead,
dense-step compute) inside a 4-device subprocess and re-runs a trainer
sweep twice — once predicting with the uncalibrated datasheet constants,
once with the fitted profile — recording mean predicted-vs-measured
step-time rel-err before/after (asserted to strictly improve) and the
noisier overlap-saving rel-err (recorded, not asserted: forced host
devices have no real NIC to overlap).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

from benchmarks.common import Row

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(REPO, "BENCH_coldstart.json")

ENGINE_STEPS = 20
TRAINER_STEPS = 6

_ENGINE_CHILD = f"""
import json, os, time
from repro.core import compilecache
compilecache.configure(os.environ["COLDSTART_CACHE"])
from repro.core.simulate import engine_cache_stats
from repro.experiments.runner import _run_training_scenarios, sweep_matrix_45

cells = sweep_matrix_45(steps={ENGINE_STEPS}, problem_seeds=(0, 1))
t0 = time.perf_counter(); _run_training_scenarios(cells, replicas=1)
first_s = time.perf_counter() - t0
t0 = time.perf_counter(); _run_training_scenarios(cells, replicas=1)
warm_process_s = time.perf_counter() - t0
st = engine_cache_stats()
print("RESULT " + json.dumps({{
    "n_cells": len(cells), "first_s": first_s,
    "warm_process_s": warm_process_s, "compiles": st.compiles,
    "persistent": st.persistent_cache}}))
"""

_TRAINER_CHILD = f"""
import json, os, time
from repro.core import compilecache
compilecache.configure(os.environ["COLDSTART_CACHE"])
from repro.experiments.trainer_substrate import run_trainer_sweep, trainer_matrix_16
from repro.train.steps import bundle_cache_stats

cells = trainer_matrix_16(steps={TRAINER_STEPS})
t0 = time.perf_counter()
results, skipped = run_trainer_sweep(cells)
first_s = time.perf_counter() - t0
assert not skipped, skipped
t0 = time.perf_counter(); run_trainer_sweep(cells)
warm_process_s = time.perf_counter() - t0
st = bundle_cache_stats()
print("RESULT " + json.dumps({{
    "n_cells": len(cells), "first_s": first_s,
    "warm_process_s": warm_process_s, "builds": st.builds, "hits": st.hits,
    "persistent": st.persistent_cache}}))
"""

_CALIBRATE_CHILD = f"""
import json, os
from repro.core import calibrate, compilecache
compilecache.configure(os.environ["COLDSTART_CACHE"])
from repro.experiments.scenario import Scenario
from repro.experiments.trainer_substrate import run_trainer_sweep, trainer_matrix_16

profile = calibrate.calibrate(steps={TRAINER_STEPS})  # saves <cache>/calibration.json

cells = trainer_matrix_16(steps={TRAINER_STEPS})
for overlap in ("sequential", "pipelined"):  # an overlap twin pair for the
    cells.append(Scenario(                   # overlap-saving rel-err leg
        sync="bsp", n_workers=4, steps={TRAINER_STEPS}, lr=0.05,
        compressor="qsgd", compressor_kwargs={{"levels": 16}},
        overlap=overlap, microbatch=2))

def relerrs(results):
    step, save = [], []
    for r in results:
        if r is None:
            continue
        m, p = r.measured, r.predicted
        step.append(abs(p["step_time_s"] - m["step_time_s"]) / m["step_time_s"])
        if "overlap_saving_s" in m and "overlap_saving_s" in p:
            save.append(abs(p["overlap_saving_s"] - m["overlap_saving_s"])
                        / max(abs(m["overlap_saving_s"]), 1e-9))
    mean = lambda xs: sum(xs) / len(xs) if xs else None
    return {{"step_time": mean(step), "overlap_saving": mean(save),
             "n_cells": len(step)}}

calibrate.set_active(None)
before, skipped = run_trainer_sweep(cells)
assert not skipped, skipped
calibrate.set_active(profile)
after, _ = run_trainer_sweep(cells)
print("RESULT " + json.dumps({{
    "profile": profile.as_dict(),
    "before": relerrs(before), "after": relerrs(after)}}))
"""


def _run_child(code: str, cache_dir: str, *, ndev: int, timeout: int = 900) -> dict:
    env = dict(os.environ)
    env["COLDSTART_CACHE"] = cache_dir
    env.pop("REPRO_CACHE_DIR", None)  # the child configures explicitly
    if ndev > 1:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    else:
        env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"coldstart child failed:\n{out.stderr[-4000:]}")
    line = [ln for ln in out.stdout.splitlines() if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def run() -> list[Row]:
    t_all = time.perf_counter()
    with tempfile.TemporaryDirectory(prefix="coldstart-cache-") as cache_dir:
        eng_cold = _run_child(_ENGINE_CHILD, cache_dir, ndev=1)
        eng_warm = _run_child(_ENGINE_CHILD, cache_dir, ndev=1)
        tr_cold = _run_child(_TRAINER_CHILD, cache_dir, ndev=4)
        tr_warm = _run_child(_TRAINER_CHILD, cache_dir, ndev=4)
        cal = _run_child(_CALIBRATE_CHILD, cache_dir, ndev=4)

        # manifest accounting: a cold cache misses every build, a warm cache
        # misses NONE (0 fresh XLA compiles on the second process)
        assert eng_cold["persistent"]["misses"] == eng_cold["compiles"], eng_cold
        assert eng_warm["persistent"]["misses"] == 0, eng_warm
        assert eng_warm["persistent"]["hits"] == eng_warm["compiles"], eng_warm
        assert tr_cold["persistent"]["misses"] == tr_cold["builds"], tr_cold
        assert tr_warm["persistent"]["misses"] == 0, tr_warm
        assert tr_warm["persistent"]["hits"] == tr_warm["builds"], tr_warm

        trainer_disk_speedup = tr_cold["first_s"] / tr_warm["first_s"]
        engine_disk_speedup = eng_cold["first_s"] / eng_warm["first_s"]
        # the acceptance criterion: warm-disk-cache cold-process trainer
        # sweep >= 3x faster than cold-cache
        assert trainer_disk_speedup >= 3.0, (trainer_disk_speedup, tr_cold, tr_warm)

        # calibration strictly improves the step-time prediction; the
        # overlap-saving leg is recorded without an assert (host-device noise)
        rel_before = cal["before"]["step_time"]
        rel_after = cal["after"]["step_time"]
        assert rel_after < rel_before, cal

    record = {
        "engine": {
            "n_cells": eng_cold["n_cells"],
            "steps": ENGINE_STEPS,
            "compiles": eng_cold["compiles"],
            "cold_cache_s": eng_cold["first_s"],
            "warm_cache_s": eng_warm["first_s"],
            "warm_process_s": eng_warm["warm_process_s"],
            "disk_speedup": engine_disk_speedup,
            "persistent_cold": eng_cold["persistent"],
            "persistent_warm": eng_warm["persistent"],
        },
        "trainer": {
            "n_cells": tr_cold["n_cells"],
            "steps": TRAINER_STEPS,
            "builds": tr_cold["builds"],
            "cache_hits": tr_cold["hits"],
            "cold_cache_s": tr_cold["first_s"],
            "warm_cache_s": tr_warm["first_s"],
            "warm_process_s": tr_warm["warm_process_s"],
            "disk_speedup": trainer_disk_speedup,
            "persistent_cold": tr_cold["persistent"],
            "persistent_warm": tr_warm["persistent"],
        },
        "calibration": {
            "profile": cal["profile"],
            "relerr_step_time_before": rel_before,
            "relerr_step_time_after": rel_after,
            "relerr_overlap_saving_before": cal["before"]["overlap_saving"],
            "relerr_overlap_saving_after": cal["after"]["overlap_saving"],
            "n_cells": cal["before"]["n_cells"],
        },
        "bench_wall_clock_s": time.perf_counter() - t_all,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(record, f, indent=2)

    return [
        Row("coldstart/engine_disk", eng_warm["first_s"] * 1e6,
            f"cold {eng_cold['first_s']:.1f}s -> warm-disk "
            f"{eng_warm['first_s']:.1f}s ({engine_disk_speedup:.2f}x, "
            f"{eng_cold['compiles']} programs)"),
        Row("coldstart/trainer_disk", tr_warm["first_s"] * 1e6,
            f"cold {tr_cold['first_s']:.1f}s -> warm-disk "
            f"{tr_warm['first_s']:.1f}s ({trainer_disk_speedup:.2f}x >= 3x, "
            f"{tr_cold['builds']} bundles)"),
        Row("coldstart/calibration", 0.0,
            f"step-time rel-err {rel_before:.2f} -> {rel_after:.2f} "
            f"(alpha={cal['profile']['alpha']:.2e}, "
            f"beta={cal['profile']['beta']:.2e})"),
        Row("coldstart/claims_validated", 0.0, True),
    ]
